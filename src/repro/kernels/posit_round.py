"""Pallas TPU kernels: fused posit rounding on the float datapath.

The PRAU-style in-register rounding: instead of materializing an encode →
decode codec round trip per elementary op, the kernel rounds a float tile
onto the posit lattice in place with the direct float-bit manipulation of
``repro.core.posit.round_posit_math`` (elementwise, no clz — Pallas-safe),
optionally fused with the producing op so each streaming butterfly / MAC is
one kernel launch instead of a dispatch chain:

* ``posit_round_2d``    — elementwise x → nearest-posit(x)
* ``posit_fma_round_2d``— round(a·b + c), one rounding (PRAU MAC)
* ``posit_butterfly_2d``— the radix-2 DIT FFT butterfly with every
  elementary op rounded, the §VI-B hot loop of the cough pipeline:
  t = w ⊗ o (4 mul + 2 add, each rounded), u = e + t, v = e − t.

On non-TPU backends the kernels run in ``interpret=True`` mode — same
kernel body — so CPU containers stay testable; ``repro.core.arith`` routes
through these kernels only when the backend profits from them (TPU), via
the ``REPRO_ROUND_BACKEND`` switch.

Tiling: (block_rows, 128) float32 tiles, lane dim a multiple of 128,
sublane a multiple of 8 — the f32 minimum tile of the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import PositFormat
from repro.core.posit import round_posit_math

from .common import pad_to_tiles as _pad_2d


def _round_kernel(x_ref, out_ref, *, fmt: PositFormat):
    out_ref[...] = round_posit_math(x_ref[...], fmt)


def _fma_round_kernel(a_ref, b_ref, c_ref, out_ref, *, fmt: PositFormat):
    out_ref[...] = round_posit_math(
        a_ref[...] * b_ref[...] + c_ref[...], fmt)


def _butterfly_kernel(er_ref, ei_ref, or_ref, oi_ref, wr_ref, wi_ref,
                      ur_ref, ui_ref, vr_ref, vi_ref, *, fmt: PositFormat):
    rnd = functools.partial(round_posit_math, fmt=fmt)
    er, ei = er_ref[...], ei_ref[...]
    o_r, o_i = or_ref[...], oi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    t_r = rnd(rnd(wr * o_r) - rnd(wi * o_i))
    t_i = rnd(rnd(wr * o_i) + rnd(wi * o_r))
    ur_ref[...] = rnd(er + t_r)
    ui_ref[...] = rnd(ei + t_i)
    vr_ref[...] = rnd(er - t_r)
    vi_ref[...] = rnd(ei - t_i)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "block_rows", "interpret"))
def posit_round_2d(x: jax.Array, fmt: PositFormat, block_rows: int = 512,
                   interpret: bool = False) -> jax.Array:
    """(M, 128·k) floats → nearest posit values, same shape/dtype."""
    M, N = x.shape
    bm, bn = min(block_rows, M), min(128, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    return pl.pallas_call(
        functools.partial(_round_kernel, fmt=fmt),
        grid=(M // bm, N // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "block_rows", "interpret"))
def posit_fma_round_2d(a: jax.Array, b: jax.Array, c: jax.Array,
                       fmt: PositFormat, block_rows: int = 512,
                       interpret: bool = False) -> jax.Array:
    """round(a·b + c) with a single rounding — the quire-style MAC."""
    M, N = a.shape
    bm, bn = min(block_rows, M), min(128, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_fma_round_kernel, fmt=fmt),
        grid=(M // bm, N // bn),
        in_specs=[spec] * 3,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        interpret=interpret,
    )(a, b, c)


@functools.partial(jax.jit,
                   static_argnames=("fmt", "block_rows", "interpret"))
def posit_butterfly_2d(e_re, e_im, o_re, o_im, w_re, w_im,
                       fmt: PositFormat, block_rows: int = 512,
                       interpret: bool = False):
    """One rounded radix-2 butterfly over (M, 128·k) planes.

    Returns (u_re, u_im, v_re, v_im) with the exact per-op rounding
    sequence of ``apps.dsp.fft_format`` — 10 rounded ops fused into one
    kernel launch instead of ten.
    """
    M, N = e_re.shape
    bm, bn = min(block_rows, M), min(128, N)
    assert M % bm == 0 and N % bn == 0, (M, N, bm, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out = jax.ShapeDtypeStruct((M, N), e_re.dtype)
    return pl.pallas_call(
        functools.partial(_butterfly_kernel, fmt=fmt),
        grid=(M // bm, N // bn),
        in_specs=[spec] * 6,
        out_specs=[spec] * 4,
        out_shape=[out] * 4,
        interpret=interpret,
    )(e_re, e_im, o_re, o_im, w_re, w_im)


def posit_round(x: jax.Array, fmt: PositFormat,
                interpret: bool | None = None) -> jax.Array:
    """Arbitrary-shape fused round (reshaped onto (rows, 128) tiles)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    mat, n, bm = _pad_2d(x)
    out = posit_round_2d(mat, fmt, block_rows=bm, interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


def posit_fma_round(a: jax.Array, b: jax.Array, c: jax.Array,
                    fmt: PositFormat,
                    interpret: bool | None = None) -> jax.Array:
    """Arbitrary-shape fused round(a·b + c) (broadcasts like jnp)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    a, b, c = jnp.broadcast_arrays(jnp.asarray(a), jnp.asarray(b),
                                   jnp.asarray(c))
    am, n, bm = _pad_2d(a)
    bmat, _, _ = _pad_2d(b)
    cmat, _, _ = _pad_2d(c)
    out = posit_fma_round_2d(am, bmat, cmat, fmt, block_rows=bm,
                             interpret=interpret)
    return out.reshape(-1)[:n].reshape(a.shape)


def posit_butterfly(e_re, e_im, o_re, o_im, w_re, w_im, fmt: PositFormat,
                    interpret: bool | None = None):
    """Arbitrary-shape batched rounded butterfly: one launch per FFT stage.

    Broadcasts the six operands together (the stage loop passes whole
    (batch, …, L, R/2) planes with the plan's twiddle constants broadcast
    along the run axis), flattens them onto the (rows, 128) f32 tiles of
    ``posit_butterfly_2d``, and unpads the four outputs.  Padding lanes
    compute garbage butterflies that are sliced away — the kernel body is
    elementwise, so real lanes are unaffected.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    arrs = jnp.broadcast_arrays(e_re, e_im, o_re, o_im, w_re, w_im)
    shape = arrs[0].shape
    mats, n, bm = [], None, None
    for a in arrs:
        m, n, bm = _pad_2d(a)
        mats.append(m)
    outs = posit_butterfly_2d(*mats, fmt, block_rows=bm, interpret=interpret)
    return tuple(o.reshape(-1)[:n].reshape(shape) for o in outs)
