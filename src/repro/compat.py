"""Version compatibility for the JAX surface this repo touches.

The codebase targets the modern mesh/shard_map API (``jax.sharding.AxisType``,
``jax.shard_map``, ``axis_names=``/``check_vma=``).  Containers in the fleet
pin older JAX (e.g. 0.4.x) where those names live elsewhere or don't exist:

* ``AxisType`` is absent → meshes are built without ``axis_types`` (every axis
  defaults to Auto there anyway, so semantics are unchanged);
* ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the kwargs
  ``check_rep=`` and ``auto=`` (the complement of ``axis_names=``).

Import mesh/shard_map helpers from here instead of from ``jax`` directly.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

try:  # modern JAX
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # older JAX: no explicit axis types (all axes are Auto)
    AxisType = None

HAS_AXIS_TYPE = AxisType is not None

try:  # modern JAX re-exports shard_map at top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
    _SHARD_MAP_MODERN = True
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_MODERN = False


def enable_x64():
    """Context manager enabling 64-bit mode (moved across JAX versions)."""
    if hasattr(jax, "enable_x64"):
        return jax.enable_x64()
    from jax.experimental import enable_x64 as _e64
    return _e64()


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (Auto,)*n}`` when supported, else ``{}``."""
    if HAS_AXIS_TYPE:
        return {"axis_types": (AxisType.Auto,) * n_axes}
    return {}


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    kw = {"devices": devices} if devices is not None else {}
    try:
        return jax.make_mesh(axis_shapes, axis_names,
                             **axis_types_kwargs(len(axis_names)), **kw)
    except TypeError:  # axis_types kwarg not accepted by this version
        return jax.make_mesh(axis_shapes, axis_names, **kw)


def device_mesh(device_array, axis_names: Sequence[str]):
    """``jax.sharding.Mesh`` over an explicit ndarray of devices."""
    from jax.sharding import Mesh
    try:
        return Mesh(device_array, axis_names,
                    **axis_types_kwargs(len(axis_names)))
    except TypeError:
        return Mesh(device_array, axis_names)


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """Spec-level mesh (no devices); handles both AbstractMesh signatures."""
    from jax.sharding import AbstractMesh
    try:  # modern: AbstractMesh(shape, names, axis_types=...)
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names),
                            **axis_types_kwargs(len(axis_names)))
    except TypeError:  # 0.4.x: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[set] = None,
              check_vma: Optional[bool] = None):
    """``shard_map`` accepting the modern kwargs on every JAX version.

    ``axis_names`` — the MANUAL axes (modern spelling).  On old JAX this is
    translated to ``auto=`` (its complement).  ``check_vma`` maps to
    ``check_rep`` on old JAX.
    """
    kw = {}
    if _SHARD_MAP_MODERN:
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
    else:
        if axis_names is not None:
            kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
        if check_vma is not None:
            kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
