"""Fault-tolerant checkpointing: atomic (tmp+rename), async, retention-N,
restore-latest-valid. Posit-quantized checkpoint option cuts the checkpoint
footprint by the storage ratio — the paper's 29% memory-image argument
applied to training state.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import PositFormat, get_format
from repro.core.posit import decode as posit_decode, encode as posit_encode


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 quantize_fmt: Optional[str] = None, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.fmt: Optional[PositFormat] = (
            get_format(quantize_fmt) if quantize_fmt else None)
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = False) -> None:
        self.wait()  # serialize with any in-flight async save (same tmp dir)
        if os.path.exists(os.path.join(self.dir, f"step-{step:09d}")):
            return  # idempotent: this step is already durable
        leaves, treedef = jax.tree_util.tree_flatten(state)
        arrays = [np.asarray(l) for l in leaves]

        def _write():
            tmp = os.path.join(self.dir, f".tmp-{step}")
            final = os.path.join(self.dir, f"step-{step:09d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            meta = {"step": step, "treedef": str(treedef),
                    "n_leaves": len(arrays),
                    "quantized": self.fmt.name if self.fmt else None}
            payload = {}
            for i, a in enumerate(arrays):
                if (self.fmt is not None and a.dtype == np.float32
                        and a.ndim >= 2):
                    bits = np.asarray(posit_encode(jnp.asarray(a), self.fmt))
                    payload[f"leaf{i}"] = bits
                    meta[f"leaf{i}_posit"] = True
                else:
                    payload[f"leaf{i}"] = a
            np.savez(os.path.join(tmp, "state.npz"), **payload)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            os.replace(tmp, final) if not os.path.exists(final) else None
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:09d}"),
                          ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step-") and os.path.exists(
                    os.path.join(self.dir, d, "meta.json")):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: Any, step: Optional[int] = None
                ) -> Tuple[Any, int]:
        """Restore into the structure of ``state_like``; returns (state, step).

        Walks back through retained checkpoints if the newest is corrupt —
        the node-failure-mid-save story.
        """
        steps = self.all_steps()
        if step is not None:
            steps = [s for s in steps if s == step]
        for s in reversed(steps):
            try:
                return self._load(state_like, s), s
            except Exception:
                continue
        raise FileNotFoundError(f"no restorable checkpoint in {self.dir}")

    def _load(self, state_like: Any, step: int) -> Any:
        d = os.path.join(self.dir, f"step-{step:09d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(d, "state.npz"))
        leaves_like, treedef = jax.tree_util.tree_flatten(state_like)
        assert meta["n_leaves"] == len(leaves_like), "structure mismatch"
        leaves = []
        for i, like in enumerate(leaves_like):
            a = data[f"leaf{i}"]
            if meta.get(f"leaf{i}_posit"):
                a = np.asarray(posit_decode(jnp.asarray(a), self.fmt,
                                            dtype=jnp.float32))
            leaves.append(jnp.asarray(a, dtype=like.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)
